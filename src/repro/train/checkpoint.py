"""Atomic, elastic checkpointing (DESIGN.md §6).

* **Atomic**: state is written to ``<dir>/tmp.<step>`` then ``os.replace``d
  into place — a crash mid-write never corrupts the latest-good pointer.
* **Elastic**: tensors are stored mesh-agnostically (host layout); restore
  ``jax.device_put``s them onto *whatever* mesh/sharding the new job uses,
  so a run checkpointed on N devices resumes on M ≠ N (tested).
* **Manifest**: step, arch name, mesh shape and leaf treedef travel with
  the payload; ``retention`` prunes old steps, keeping every ``keep_every``.

At 1000+-node scale the same layout shards the save across hosts (each
host writes the leaves it owns); the single-process container exercises
the full logic minus the multi-writer fan-out.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

SEP = "//"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(
    directory: str,
    step: int,
    state: Any,
    *,
    metadata: dict | None = None,
) -> str:
    """Atomically write ``state`` under ``directory/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f"tmp.{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "num_leaves": len(flat),
        "keys": sorted(flat),
        **(metadata or {}),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_")
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    like: Any,
    *,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure, NamedSharding
    leaves) re-shards onto the current mesh — elastic restore."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))

    leaves_with_path = jax.tree_util.tree_leaves_with_path(like)
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else None
    )
    out = []
    for i, (p, leaf) in enumerate(leaves_with_path):
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in p
        )
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        host = arrays[key]
        if tuple(host.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {host.shape} != model {leaf.shape}"
            )
        host = host.astype(leaf.dtype)
        if shard_leaves is not None:
            out.append(jax.device_put(host, shard_leaves[i]))
        else:
            out.append(jnp.asarray(host))
    return treedef.unflatten(out), manifest


def retention(directory: str, *, keep_last: int = 3, keep_every: int = 0) -> None:
    """Prune old checkpoints: always keep the newest ``keep_last``; also
    keep any step divisible by ``keep_every`` (0 = off)."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_")
    )
    protected = set(steps[-keep_last:]) if keep_last else set()
    if keep_every:
        protected |= {s for s in steps if s % keep_every == 0}
    for s in steps:
        if s not in protected:
            shutil.rmtree(os.path.join(directory, f"step_{s:08d}"))
