"""Execute the README's ```python code fences (the CI docs job).

Fences share one namespace and run top-to-bottom, so the README can
build up an example across fences. A fence whose first line is
``# docs: no-run`` is skipped (for illustrative fragments). Exits
nonzero on the first broken fence — a README whose quickstart doesn't
run is a bug.

Run from the repo root: PYTHONPATH=src python tools/check_readme.py
"""

from __future__ import annotations

import pathlib
import re
import sys

README = pathlib.Path(__file__).resolve().parent.parent / "README.md"


def main() -> int:
    text = README.read_text()
    fences = re.findall(r"```python\n(.*?)```", text, re.S)
    if not fences:
        print("error: README.md has no ```python fences to check", file=sys.stderr)
        return 1
    ns: dict = {}
    ran = 0
    for i, code in enumerate(fences, 1):
        if code.lstrip().startswith("# docs: no-run"):
            print(f"-- fence {i}/{len(fences)}: skipped (no-run) --")
            continue
        print(f"-- fence {i}/{len(fences)} --", flush=True)
        exec(compile(code, f"README.md#fence{i}", "exec"), ns)
        ran += 1
    print(f"README OK: {ran}/{len(fences)} python fences executed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
