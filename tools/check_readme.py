"""Execute the ```python code fences of README.md and the docs pages
listed in :data:`FENCED_DOCS` (the CI docs job).

Within one file, fences share one namespace and run top-to-bottom, so a
page can build up an example across fences (namespaces do NOT leak
between files). A fence whose first line is ``# docs: no-run`` is
skipped (for illustrative fragments). Exits nonzero on the first broken
fence — a page whose quickstart doesn't run is a bug.

``--examples`` additionally executes the quick-mode example scripts
listed in :data:`QUICK_EXAMPLES` as subprocesses (same interpreter,
``PYTHONPATH=src`` inherited), so the documented quickstarts cannot rot
either.

Run from the repo root: PYTHONPATH=src python tools/check_readme.py
"""

from __future__ import annotations

import argparse
import pathlib
import re
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
README = REPO_ROOT / "README.md"

# Docs pages whose ```python fences must execute (relative to the repo
# root; README.md is always checked and must contain fences).
FENCED_DOCS = [
    "docs/architecture.md",
    "docs/robustness.md",
    "docs/serving.md",
    "docs/tuning.md",
]

# Example scripts with a fast deterministic mode, run by the CI docs job
# (script path relative to the repo root, plus its quick-mode args).
# The --shards run exercises the mesh-sharded serving path on 2 fake
# host devices (the flag sets XLA_FLAGS before the jax import); the
# --tuned run serves through the committed autotuner table
# (examples/tuning_table.json) and asserts the tuned plan bills no
# more grid steps than the default.
QUICK_EXAMPLES = [
    ("examples/serve_stream.py", ["--quick"]),
    ("examples/serve_stream.py", ["--quick", "--shards", "2"]),
    ("examples/serve_stream.py", ["--quick", "--tuned"]),
]


def run_file_fences(path: pathlib.Path, *, require: bool) -> int:
    text = path.read_text()
    rel = path.relative_to(REPO_ROOT)
    fences = re.findall(r"```python\n(.*?)```", text, re.S)
    if not fences:
        if require:
            print(
                f"error: {rel} has no ```python fences to check",
                file=sys.stderr,
            )
            return 1
        print(f"{rel}: no python fences")
        return 0
    ns: dict = {}
    ran = 0
    for i, code in enumerate(fences, 1):
        if code.lstrip().startswith("# docs: no-run"):
            print(f"-- {rel} fence {i}/{len(fences)}: skipped (no-run) --")
            continue
        print(f"-- {rel} fence {i}/{len(fences)} --", flush=True)
        exec(compile(code, f"{rel}#fence{i}", "exec"), ns)
        ran += 1
    print(f"{rel} OK: {ran}/{len(fences)} python fences executed")
    return 0


def run_fences() -> int:
    rc = run_file_fences(README, require=True)
    for doc in FENCED_DOCS:
        if rc != 0:
            break
        rc = run_file_fences(REPO_ROOT / doc, require=False)
    return rc


def run_examples() -> int:
    for script, args in QUICK_EXAMPLES:
        cmd = [sys.executable, str(REPO_ROOT / script), *args]
        print(f"-- example: {script} {' '.join(args)} --", flush=True)
        r = subprocess.run(cmd, cwd=REPO_ROOT)
        if r.returncode != 0:
            print(
                f"error: {script} exited {r.returncode}", file=sys.stderr
            )
            return r.returncode
    print(f"examples OK: {len(QUICK_EXAMPLES)} quick-mode scripts executed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--examples",
        action="store_true",
        help="also run the quick-mode example scripts",
    )
    args = ap.parse_args()
    rc = run_fences()
    if rc == 0 and args.examples:
        rc = run_examples()
    return rc


if __name__ == "__main__":
    sys.exit(main())
