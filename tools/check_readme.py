"""Execute the README's ```python code fences (the CI docs job).

Fences share one namespace and run top-to-bottom, so the README can
build up an example across fences. A fence whose first line is
``# docs: no-run`` is skipped (for illustrative fragments). Exits
nonzero on the first broken fence — a README whose quickstart doesn't
run is a bug.

``--examples`` additionally executes the quick-mode example scripts
listed in :data:`QUICK_EXAMPLES` as subprocesses (same interpreter,
``PYTHONPATH=src`` inherited), so the documented quickstarts cannot rot
either.

Run from the repo root: PYTHONPATH=src python tools/check_readme.py
"""

from __future__ import annotations

import argparse
import pathlib
import re
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
README = REPO_ROOT / "README.md"

# Example scripts with a fast deterministic mode, run by the CI docs job
# (script path relative to the repo root, plus its quick-mode args).
QUICK_EXAMPLES = [
    ("examples/serve_stream.py", ["--quick"]),
]


def run_fences() -> int:
    text = README.read_text()
    fences = re.findall(r"```python\n(.*?)```", text, re.S)
    if not fences:
        print("error: README.md has no ```python fences to check", file=sys.stderr)
        return 1
    ns: dict = {}
    ran = 0
    for i, code in enumerate(fences, 1):
        if code.lstrip().startswith("# docs: no-run"):
            print(f"-- fence {i}/{len(fences)}: skipped (no-run) --")
            continue
        print(f"-- fence {i}/{len(fences)} --", flush=True)
        exec(compile(code, f"README.md#fence{i}", "exec"), ns)
        ran += 1
    print(f"README OK: {ran}/{len(fences)} python fences executed")
    return 0


def run_examples() -> int:
    for script, args in QUICK_EXAMPLES:
        cmd = [sys.executable, str(REPO_ROOT / script), *args]
        print(f"-- example: {script} {' '.join(args)} --", flush=True)
        r = subprocess.run(cmd, cwd=REPO_ROOT)
        if r.returncode != 0:
            print(
                f"error: {script} exited {r.returncode}", file=sys.stderr
            )
            return r.returncode
    print(f"examples OK: {len(QUICK_EXAMPLES)} quick-mode scripts executed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--examples",
        action="store_true",
        help="also run the quick-mode example scripts",
    )
    args = ap.parse_args()
    rc = run_fences()
    if rc == 0 and args.examples:
        rc = run_examples()
    return rc


if __name__ == "__main__":
    sys.exit(main())
