"""CI perf-regression gate over ``BENCH_kernels.json``.

Compares a fresh benchmark artifact against the committed baseline
(``benchmarks/baselines/BENCH_kernels.baseline.json``) and FAILS the job
with a readable diff table instead of merely uploading the artifact.

Three classes of check, matched to what each field can promise:

* **exact** — grid-step counts, pallas_call counts, layouts, and the
  serve arm's deterministic accounting (rows, padded slots, engine
  steps, latency). These are hardware-independent architecture truth:
  any drift is a real behaviour change and fails the gate.
* **tolerant** — wall-clock fields. Runner noise dominates, so the gate
  only rejects order-of-magnitude blowups (``--tol``, default 25x).
* **non-regression** — the serve arm's continuous-batching pad-slot
  fraction must not exceed the baseline's, and must stay strictly below
  the static arm's (the whole point of the scheduler).

Sections whose generator parameters differ from the baseline (e.g. a
full run compared against the quick baseline) are reported as SKIP, not
failed — the gate only compares like with like. Baseline topologies must
all be present in the fresh artifact (the quick grid is a subset of the
full grid). An arm present in the fresh artifact but ABSENT from the
baseline (a newly added arm, mid-PR) is a warn + SKIP, never a crash:
the gate keeps passing until the baseline is refreshed to cover it.

Run from the repo root:
  PYTHONPATH=src python -m benchmarks.kernel_bench --quick
  python tools/check_bench.py
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_FRESH = REPO_ROOT / "BENCH_kernels.json"
DEFAULT_BASELINE = (
    REPO_ROOT / "benchmarks" / "baselines" / "BENCH_kernels.baseline.json"
)

# Per-section generator parameters: a section is only compared when ALL
# of these match between baseline and fresh artifact.
PARAMS = {
    "fused": ("m", "layers", "blocks_per_row", "n"),
    "train": ("m", "layers", "block", "blocks_per_row", "n"),
    "serve": (
        "m",
        "layers",
        "blocks_per_row",
        "requests",
        "batch_size",
        "tile_align",
        "min_fill",
        "max_wait",
        "trace",
    ),
    "plan": (
        "m",
        "layers",
        "blocks_per_row",
        "requests",
        "batch_size",
        "tile_align",
        "width_classes",
        "trace",
        "train_params",
    ),
    "sharded": ("m", "layers", "block", "blocks_per_row", "n", "shards"),
    "faults": (
        "m",
        "layers",
        "blocks_per_row",
        "requests",
        "batch_size",
        "tile_align",
        "seed",
    ),
    "challenge": (
        "neurons",
        "layers",
        "n_inputs",
        "panel_width",
        "batch_align",
        "density",
        "seed",
    ),
    "gnn": (
        "m",
        "block",
        "total_blocks",
        "skew",
        "feat_dim",
        "rounds",
        "bf_iters_cap",
        "seed",
    ),
    "tune": ("params",),
    "fleet": (
        "m",
        "layers",
        "blocks_per_row",
        "duration_s",
        "seed",
        "replicas",
        "rate_factors",
        "miss_budget",
        "profile",
        "width_classes",
        "width_mix",
        "deadline_s",
        "service_model",
        "max_pending_cols",
    ),
}

EXACT = {
    "fused": (
        "pallas_calls_fused",
        "pallas_calls_layered",
        "hbm_activation_roundtrips_eliminated",
    ),
    "train": (
        "pallas_calls_per_step",
        "pallas_calls_forward_only",
        "grid_steps_forward",
        "grid_steps_backward_kernel",
        "layout_per_layer",
        "weight_cotangent_pattern_preserved",
        "loss_decreased",
    ),
}
# Plan arm (compiled execution plans): deterministic accounting checked
# exactly, wall-clocks tolerantly, and the headline amortization gated.
PLAN_SERVE_EXACT = (
    "engine_steps",
    "rows_served",
    "padded_slots",
    "pad_slot_fraction",
    "grid_steps_total",
    "plan_lookups",
    "plan_builds",
    "plan_evictions",
    "cache_hit_rate",
    "recompiles_by_class",
)
PLAN_TRAIN_EXACT = (
    "layout_per_layer",
    "csr_layers",
    "sorts_at_plan_build",
    "sorts_total",
    "legacy_jaxpr_has_sort",
    "planned_jaxpr_has_sort",
    "loss_decreased",
    "losses_match_legacy",
)
TOPOLOGY_EXACT = (
    "grid_steps_ell",
    "grid_steps_csr",
    "max_blocks_per_row",
    "mean_blocks_per_row",
)
# Sharding arm (balanced block-CSR partitioner): all host-side
# deterministic accounting — per-shard nnz/bills, the bill-equality
# invariant, and the load-imbalance factor are checked exactly.
SHARDED_EXACT = (
    "nnz_blocks_total",
    "nnz_per_shard",
    "grid_steps_unsharded",
    "grid_steps_per_shard",
    "grid_steps_sharded_total",
    "shard_pad_blocks",
    "bill_matches_unsharded",
    "imbalance",
    "critical_path_steps",
    "parallel_speedup_bound",
)
# Robustness arm (fault injection + graceful degradation): every fault
# is SCHEDULED, so the whole faulted run is deterministic — loss
# buckets, goodput, degradation levels and the train replay are all
# checked exactly; wall-clock tolerantly. New fields get warn+SKIP
# against older baselines (same convention as plan/sharded).
FAULTS_SERVE_EXACT = (
    "completed",
    "engine_steps",
    "deadline_misses",
    "goodput",
    "faults",
    "shed_fraction",
    "injector_fired",
    "injector_pending",
)
FAULTS_DEGRADE_EXACT = (
    "levels",
    "recovery_steps",
    "matches_single_device_after_failure",
    "ladder_events",
    "degraded",
)
FAULTS_TRAIN_EXACT = (
    "steps",
    "skipped_steps",
    "restarts",
    "losses_match_clean",
    "loss_decreased",
)
# Challenge arm (GraphChallenge workload): the topology, routing, and
# the answer set are all deterministic given the generator params —
# checked exactly; the official edges×inputs/sec rate rides on
# wall-clock and is only gated against blowups.
CHALLENGE_EXACT = (
    "bias",
    "fan_in",
    "edges",
    "routes",
    "levels",
    "width_classes",
    "engine_steps",
    "served",
    "grid_steps",
    "n_categories",
    "reference_match",
)
# GNN arm (semiring-kernel routing): layouts, grid-step bills,
# pallas_call counts, plan-cache traffic, and the Bellman-Ford fixpoint
# are pure functions of the seeded topology — checked exactly; the
# convolution's scale-normalized error float rides on the runner's
# accumulation order and is gated via the conv_matches_oracle bool.
GNN_EXACT = (
    "source_layout",
    "exec_layout",
    "kernel_grid_steps",
    "xla_sparse_grid_steps",
    "mxv_grid_steps",
    "pallas_calls_conv",
    "pallas_calls_oracle",
    "conv_matches_oracle",
    "conv_plan_builds",
    "conv_plan_hits",
    "bf_iters",
    "bf_converged",
    "bf_reachable",
    "bf_matches_numpy",
    "bf_plan_hits",
)
# Tune arm (autotuner sweep): winners, routes, and the cost-model bills
# are pure functions of the generator params — checked exactly; probe
# numerics (max-abs-err floats) and wall-clocks ride on the runner and
# are gated via headline invariants / the time tolerance instead.
TUNE_SKEWED_EXACT = (
    "winner",
    "route_tuned",
    "route_default",
    "grid_steps_tuned",
    "grid_steps_default",
    "block_work_tuned",
    "block_work_default",
    "accuracy_ok",
)
TUNE_RADIX_EXACT = (
    "winner",
    "route_default",
    "route_tuned",
    "grid_steps_default",
    "grid_steps_tuned",
    "vmem_bytes_f32",
    "vmem_bytes_bf16",
    "vmem_soft_limit",
)
# Fleet arm (replicated serving on a virtual clock): every curve point
# is a pure function of the generator config — latencies, miss rates,
# throughput and routing/plan-cache accounting are all checked exactly;
# only the arm's own wall_time_s (real compute time of the sweep) is
# gated tolerantly.
FLEET_POINT_EXACT = (
    "offered_jobs",
    "served_jobs",
    "failed_jobs",
    "rejected_jobs",
    "deadline_misses",
    "miss_rate",
    "latency_p50_s",
    "latency_p99_s",
    "latency_max_s",
    "throughput_cols_per_s",
    "goodput_cols_per_s",
    "plan_hit_rate",
    "cross_replica_compiles",
    "routing",
)
# Deterministic serve accounting, checked exactly for BOTH arms.
SERVE_EXACT = (
    "requests",
    "engine_steps",
    "rows_served",
    "padded_slots",
    "grid_steps_total",
    "latency_mean",
    "latency_p50",
    "latency_max",
    "deadline_misses",
)


class Gate:
    def __init__(self, tol: float):
        self.tol = tol
        self.rows: list[tuple[str, str, str, str, str]] = []
        self.failed = 0

    def _add(self, section, field, base, fresh, verdict):
        self.rows.append(
            (section, field, _fmt(base), _fmt(fresh), verdict)
        )
        if verdict == "FAIL":
            self.failed += 1

    def exact(self, section, field, base, fresh):
        ok = (
            math.isclose(base, fresh, rel_tol=1e-9, abs_tol=1e-12)
            if isinstance(base, float) or isinstance(fresh, float)
            else base == fresh
        )
        self._add(section, field, base, fresh, "ok" if ok else "FAIL")

    def time(self, section, field, base, fresh):
        ok = fresh <= base * self.tol
        self._add(
            section, field, base, fresh, "ok" if ok else "FAIL"
        )

    def no_worse(self, section, field, base, fresh, eps=1e-9):
        ok = fresh <= base + eps
        self._add(section, field, base, fresh, "ok" if ok else "FAIL")

    def skip(self, section, reason):
        self.rows.append((section, reason, "-", "-", "SKIP"))

    def missing(self, section, what):
        self._add(section, what, "present", "missing", "FAIL")

    def table(self) -> str:
        header = ("section", "field", "baseline", "fresh", "verdict")
        rows = [header, *self.rows]
        widths = [max(len(r[i]) for r in rows) for i in range(5)]
        lines = []
        for j, r in enumerate(rows):
            lines.append(
                "  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
            )
            if j == 0:
                lines.append("  ".join("-" * w for w in widths))
        return "\n".join(lines)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    return s if len(s) <= 32 else s[:29] + "..."


def _topo_key(t: dict) -> tuple:
    return (t["m"], t["block"], t["n"], t["nnz_blocks"], t["skew"])


def _params_match(section: str, base: dict, fresh: dict) -> bool:
    return all(base.get(k) == fresh.get(k) for k in PARAMS[section])


def _section_pair(gate: Gate, section: str, baseline: dict, fresh: dict):
    """(baseline_arm, fresh_arm) when comparable, else None.

    A fresh arm with no baseline counterpart is a newly added arm: warn
    and SKIP so adding an arm never breaks the gate mid-PR (refresh the
    baseline to start gating it). A baseline arm MISSING from the fresh
    artifact is a real regression and fails.
    """
    bs, fs = baseline.get(section), fresh.get(section)
    if bs is None:
        if fs is not None:
            gate.skip(section, "absent from baseline (new arm?)")
            print(
                f"warning: section {section!r} is in the fresh artifact "
                "but not the baseline — skipping; refresh the baseline "
                "to gate it",
                file=sys.stderr,
            )
        return None
    if fs is None:
        gate.missing(section, "section")
        return None
    if not _params_match(section, bs, fs):
        gate.skip(section, "generator params differ from baseline")
        return None
    return bs, fs


def check(baseline: dict, fresh: dict, tol: float) -> Gate:
    gate = Gate(tol)

    # --- topologies: every baseline topology must appear, steps exact --
    fresh_topos = {_topo_key(t): t for t in fresh.get("topologies", [])}
    for bt in baseline.get("topologies", []):
        key = _topo_key(bt)
        name = f"topo m={key[0]} nnz={key[3]} skew={key[4]}"
        ft = fresh_topos.get(key)
        if ft is None:
            gate.missing(name, "topology")
            continue
        for field in TOPOLOGY_EXACT:
            gate.exact(name, field, bt[field], ft[field])
        for arm in ("ell", "csr", "dense"):
            gate.time(
                name,
                f"xla_time_s.{arm}",
                bt["xla_time_s"][arm],
                ft["xla_time_s"][arm],
            )

    # --- fused / train: exact counts when the generator params match ---
    for section in ("fused", "train"):
        pair = _section_pair(gate, section, baseline, fresh)
        if pair is None:
            continue
        bs, fs = pair
        for field in EXACT[section]:
            if field not in bs:
                gate.skip(section, f"{field} absent from baseline")
                continue
            if field not in fs:
                gate.missing(section, field)
                continue
            gate.exact(section, field, bs[field], fs[field])
        for field, bt in bs.get("xla_time_s", {}).items():
            ft = fs.get("xla_time_s", {}).get(field)
            if ft is None:
                gate.missing(section, f"xla_time_s.{field}")
                continue
            gate.time(section, f"xla_time_s.{field}", bt, ft)

    # --- plan: compiled-plan amortization (exact) + wall-clocks -------
    pair = _section_pair(gate, "plan", baseline, fresh)
    if pair is not None:
        bs, fs = pair
        for sub, fields in (
            ("serve", PLAN_SERVE_EXACT),
            ("train", PLAN_TRAIN_EXACT),
        ):
            for field in fields:
                bv = bs.get(sub, {}).get(field)
                fv = fs.get(sub, {}).get(field)
                if bv is None:
                    # field newer than the committed baseline: warn+skip
                    gate.skip(f"plan.{sub}", f"{field} absent from baseline")
                    continue
                if fv is None:
                    gate.missing(f"plan.{sub}", field)
                    continue
                gate.exact(f"plan.{sub}", field, bv, fv)
        # headline: the cache hit rate must never regress below baseline
        hit_b = bs.get("serve", {}).get("cache_hit_rate")
        hit_f = fs.get("serve", {}).get("cache_hit_rate", 0.0)
        if hit_b is not None:
            gate._add(
                "plan",
                "cache_hit_rate >= baseline",
                hit_b,
                hit_f,
                "ok" if hit_f >= hit_b - 1e-9 else "FAIL",
            )
        wt_b = bs.get("serve", {}).get("wall_time_s")
        wt_f = fs.get("serve", {}).get("wall_time_s")
        if wt_b is not None and wt_f is not None:
            gate.time("plan", "serve.wall_time_s", wt_b, wt_f)
        for arm in ("legacy", "planned"):
            st_b = bs.get("train", {}).get("step_time_s", {}).get(arm)
            st_f = fs.get("train", {}).get("step_time_s", {}).get(arm)
            if st_b is not None and st_f is not None:
                gate.time("plan", f"train.step_time_s.{arm}", st_b, st_f)

    # --- sharded: partitioner accounting, all exact -------------------
    pair = _section_pair(gate, "sharded", baseline, fresh)
    if pair is not None:
        bs, fs = pair
        for field in SHARDED_EXACT:
            if field not in bs:
                gate.skip("sharded", f"{field} absent from baseline")
                continue
            if field not in fs:
                gate.missing("sharded", field)
                continue
            gate.exact("sharded", field, bs[field], fs[field])
        # headline invariants hold regardless of baseline drift: the
        # per-shard bills must sum to the unsharded bill and the
        # partitioner must stay within the 10 % imbalance budget
        gate._add(
            "sharded",
            "bills sum to unsharded",
            True,
            fs.get("bill_matches_unsharded", False),
            "ok" if fs.get("bill_matches_unsharded", False) else "FAIL",
        )
        imbalance = fs.get("imbalance")
        if imbalance is None:
            gate.missing("sharded", "imbalance")
        else:
            gate.no_worse("sharded", "imbalance <= 1.10", 1.10, imbalance)

    # --- faults: scheduled-fault determinism + robustness headlines ---
    pair = _section_pair(gate, "faults", baseline, fresh)
    if pair is not None:
        bs, fs = pair
        for sub, fields in (
            ("serve", FAULTS_SERVE_EXACT),
            ("degrade", FAULTS_DEGRADE_EXACT),
            ("train", FAULTS_TRAIN_EXACT),
        ):
            for field in fields:
                bv = bs.get(sub, {}).get(field)
                fv = fs.get(sub, {}).get(field)
                if bv is None:
                    gate.skip(f"faults.{sub}", f"{field} absent from baseline")
                    continue
                if fv is None:
                    gate.missing(f"faults.{sub}", field)
                    continue
                gate.exact(f"faults.{sub}", field, bv, fv)
        # headline invariants, gated regardless of baseline drift:
        # goodput holds its floor, shard failure degrades with identical
        # results, and the NaN-lossed train run replays a clean one
        goodput = fs.get("serve", {}).get("goodput")
        if goodput is None:
            gate.missing("faults", "serve.goodput")
        else:
            gate._add(
                "faults",
                "serve.goodput >= 0.8",
                0.8,
                goodput,
                "ok" if goodput >= 0.8 else "FAIL",
            )
        for sub, field in (
            ("degrade", "matches_single_device_after_failure"),
            ("train", "losses_match_clean"),
        ):
            ok = fs.get(sub, {}).get(field, False)
            gate._add(
                "faults", f"{sub}.{field}", True, ok, "ok" if ok else "FAIL"
            )
        wt_b = bs.get("serve", {}).get("wall_time_s")
        wt_f = fs.get("serve", {}).get("wall_time_s")
        if wt_b is not None and wt_f is not None:
            gate.time("faults", "serve.wall_time_s", wt_b, wt_f)

    # --- challenge: conformance exact, official rate gated tolerantly -
    pair = _section_pair(gate, "challenge", baseline, fresh)
    if pair is not None:
        bs, fs = pair
        for field in CHALLENGE_EXACT:
            if field not in bs:
                gate.skip("challenge", f"{field} absent from baseline")
                continue
            if field not in fs:
                gate.missing("challenge", field)
                continue
            gate.exact("challenge", field, bs[field], fs[field])
        # headline invariant, gated regardless of baseline drift: the
        # streamed engine answer set must match the numpy ground truth
        match = fs.get("reference_match", False)
        gate._add(
            "challenge",
            "reference_match",
            True,
            match,
            "ok" if match else "FAIL",
        )
        wt_b, wt_f = bs.get("wall_time_s"), fs.get("wall_time_s")
        if wt_b is not None and wt_f is not None:
            gate.time("challenge", "wall_time_s", wt_b, wt_f)

    # --- gnn: semiring-kernel routing exact, headline wins gated ------
    pair = _section_pair(gate, "gnn", baseline, fresh)
    if pair is not None:
        bs, fs = pair
        for field in GNN_EXACT:
            if field not in bs:
                gate.skip("gnn", f"{field} absent from baseline")
                continue
            if field not in fs:
                gate.missing("gnn", field)
                continue
            gate.exact("gnn", field, bs[field], fs[field])
        # headline invariants, gated regardless of baseline drift: the
        # kernel route must launch (and the oracle route must not), its
        # bill must STRICTLY beat the occupancy-equivalent XLA sparse
        # path, and the min_plus Bellman-Ford relaxation must reach the
        # numpy reference fixpoint bit-for-bit.
        launched = (
            fs.get("pallas_calls_conv", 0) >= 1
            and fs.get("pallas_calls_oracle", 1) == 0
        )
        gate._add(
            "gnn",
            "mxm_launches_kernel_route",
            True,
            launched,
            "ok" if launched else "FAIL",
        )
        beat = (
            fs.get("kernel_grid_steps", 1 << 62)
            < fs.get("xla_sparse_grid_steps", 0)
        )
        gate._add(
            "gnn",
            "kernel_beats_xla_sparse_steps",
            True,
            beat,
            "ok" if beat else "FAIL",
        )
        bf_ok = (
            fs.get("bf_converged", False)
            and fs.get("bf_matches_numpy", False)
        )
        gate._add(
            "gnn",
            "bellman_ford_matches_numpy",
            True,
            bf_ok,
            "ok" if bf_ok else "FAIL",
        )
        wt_b, wt_f = bs.get("wall_time_s"), fs.get("wall_time_s")
        if wt_b is not None and wt_f is not None:
            gate.time("gnn", "wall_time_s", wt_b, wt_f)

    # --- tune: sweep accounting exact, headline wins gated ------------
    pair = _section_pair(gate, "tune", baseline, fresh)
    if pair is not None:
        bs, fs = pair
        for sub, fields in (
            ("skewed", TUNE_SKEWED_EXACT),
            ("radix", TUNE_RADIX_EXACT),
        ):
            bsub, fsub = bs.get(sub, {}), fs.get(sub, {})
            for field in fields:
                if field not in bsub:
                    gate.skip("tune", f"{sub}.{field} absent from baseline")
                    continue
                if field not in fsub:
                    gate.missing("tune", f"{sub}.{field}")
                    continue
                gate.exact("tune", f"{sub}.{field}", bsub[field], fsub[field])
        # headline invariants, gated regardless of baseline drift: the
        # tuned config must STRICTLY beat the default's grid-step bill
        # on the skewed stack, bf16 panels must move the RadiX-net
        # stack across the resident boundary, and the bf16 numerics
        # must hold on the challenge-shaped probe.
        sk, rad = fs.get("skewed", {}), fs.get("radix", {})
        won = (
            sk.get("grid_steps_tuned", 1 << 62)
            < sk.get("grid_steps_default", 0)
        )
        gate._add(
            "tune",
            "skewed.tuned_beats_default_steps",
            True,
            won,
            "ok" if won else "FAIL",
        )
        moved = (
            rad.get("route_default") == "fused-tiled"
            and rad.get("route_tuned") == "fused"
        )
        gate._add(
            "tune",
            "radix.bf16_moves_resident_boundary",
            True,
            moved,
            "ok" if moved else "FAIL",
        )
        err = rad.get("bf16_max_abs_err")
        err_ok = err is not None and err <= 0.05
        gate._add(
            "tune",
            "radix.bf16_max_abs_err<=0.05",
            True,
            err_ok,
            "ok" if err_ok else "FAIL",
        )
        for sub, field in (
            ("skewed", "wall_s_tuned"),
            ("skewed", "wall_s_default"),
            ("radix", "wall_s_f32_tiled"),
            ("radix", "wall_s_bf16_tiled"),
        ):
            wt_b = bs.get(sub, {}).get(field)
            wt_f = fs.get(sub, {}).get(field)
            if wt_b is not None and wt_f is not None:
                gate.time("tune", f"{sub}.{field}", wt_b, wt_f)

    # --- fleet: replicated-serving curves exact, headlines gated ------
    pair = _section_pair(gate, "fleet", baseline, fresh)
    if pair is not None:
        bs, fs = pair
        for arm in ("single", "fleet"):
            b_pts = bs.get("curves", {}).get(arm, [])
            f_pts = fs.get("curves", {}).get(arm, [])
            if len(b_pts) != len(f_pts):
                gate.missing(f"fleet.{arm}", "curve points")
                continue
            for bp, fp in zip(b_pts, f_pts):
                name = f"fleet.{arm}@x{bp.get('rate_factor', '?')}"
                for field in FLEET_POINT_EXACT:
                    if field not in bp:
                        gate.skip(name, f"{field} absent from baseline")
                        continue
                    if field not in fp:
                        gate.missing(name, field)
                        continue
                    gate.exact(name, field, bp[field], fp[field])
        for field in ("sustained_jobs_per_s", "fleet_plan_hit_rate_min"):
            if field not in bs:
                gate.skip("fleet", f"{field} absent from baseline")
            elif field not in fs:
                gate.missing("fleet", field)
            else:
                gate.exact("fleet", field, bs[field], fs[field])
        # headline invariants, gated regardless of baseline drift: the
        # replicated fleet must sustain strictly more offered load than
        # one engine at the same miss budget, and the affinity router
        # must hold the fleet-wide plan-cache hit rate at >= 0.9
        sus = fs.get("sustained_jobs_per_s", {})
        single_s, fleet_s = sus.get("single"), sus.get("fleet")
        if single_s is None or fleet_s is None:
            gate.missing("fleet", "sustained_jobs_per_s")
        else:
            gate._add(
                "fleet",
                "sustained: fleet > single",
                single_s,
                fleet_s,
                "ok" if fleet_s > single_s else "FAIL",
            )
        hit = fs.get("fleet_plan_hit_rate_min")
        if hit is None:
            gate.missing("fleet", "fleet_plan_hit_rate_min")
        else:
            gate._add(
                "fleet",
                "plan_hit_rate_min >= 0.9",
                0.9,
                hit,
                "ok" if hit >= 0.9 else "FAIL",
            )
        wt_b, wt_f = bs.get("wall_time_s"), fs.get("wall_time_s")
        if wt_b is not None and wt_f is not None:
            gate.time("fleet", "wall_time_s", wt_b, wt_f)

    # --- serve: deterministic accounting exact, pad waste gated -------
    pair = _section_pair(gate, "serve", baseline, fresh)
    if pair is not None:
        bs, fs = pair
        gate.exact(
            "serve", "resident_path_used",
            bs["resident_path_used"], fs["resident_path_used"],
        )
        for arm in ("static", "continuous"):
            for field in SERVE_EXACT:
                gate.exact(
                    f"serve.{arm}", field, bs[arm][field], fs[arm][field]
                )
        # the headline guarantee: pad waste must not regress vs the
        # baseline, and continuous must still beat static outright
        gate.no_worse(
            "serve",
            "continuous.pad_slot_fraction",
            bs["continuous"]["pad_slot_fraction"],
            fs["continuous"]["pad_slot_fraction"],
        )
        strict = (
            fs["continuous"]["pad_slot_fraction"]
            < fs["static"]["pad_slot_fraction"]
        )
        gate._add(
            "serve",
            "continuous < static pad fraction",
            fs["static"]["pad_slot_fraction"],
            fs["continuous"]["pad_slot_fraction"],
            "ok" if strict else "FAIL",
        )
        for arm in ("static", "continuous"):
            gate.time(
                "serve",
                f"wall_time_s.{arm}",
                bs["wall_time_s"][arm],
                fs["wall_time_s"][arm],
            )
    return gate


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", nargs="?", default=str(DEFAULT_FRESH))
    ap.add_argument("baseline", nargs="?", default=str(DEFAULT_BASELINE))
    ap.add_argument(
        "--tol",
        type=float,
        default=25.0,
        help="wall-clock regression factor tolerated (runner noise)",
    )
    args = ap.parse_args()

    try:
        fresh = json.loads(pathlib.Path(args.fresh).read_text())
    except FileNotFoundError:
        print(
            f"error: fresh artifact {args.fresh} not found — run "
            "`PYTHONPATH=src python -m benchmarks.kernel_bench --quick` first",
            file=sys.stderr,
        )
        return 2
    baseline = json.loads(pathlib.Path(args.baseline).read_text())

    gate = check(baseline, fresh, args.tol)
    print(gate.table())
    n_checks = sum(1 for r in gate.rows if r[4] != "SKIP")
    if gate.failed:
        print(
            f"\nbench gate: {gate.failed}/{n_checks} checks FAILED against "
            f"{args.baseline}",
            file=sys.stderr,
        )
        print(
            "If the change is intentional (new kernel schedule, new "
            "trace), regenerate the baseline:\n"
            "  PYTHONPATH=src python -m benchmarks.kernel_bench --quick\n"
            f"  cp {DEFAULT_FRESH.name} {args.baseline}",
            file=sys.stderr,
        )
        return 1
    print(f"\nbench gate: all {n_checks} checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
