"""CI perf-regression gate over ``BENCH_kernels.json``.

Compares a fresh benchmark artifact against the committed baseline
(``benchmarks/baselines/BENCH_kernels.baseline.json``) and FAILS the job
with a readable diff table instead of merely uploading the artifact.

Three classes of check, matched to what each field can promise:

* **exact** — grid-step counts, pallas_call counts, layouts, and the
  serve arm's deterministic accounting (rows, padded slots, engine
  steps, latency). These are hardware-independent architecture truth:
  any drift is a real behaviour change and fails the gate.
* **tolerant** — wall-clock fields. Runner noise dominates, so the gate
  only rejects order-of-magnitude blowups (``--tol``, default 25x).
* **non-regression** — the serve arm's continuous-batching pad-slot
  fraction must not exceed the baseline's, and must stay strictly below
  the static arm's (the whole point of the scheduler).

Sections whose generator parameters differ from the baseline (e.g. a
full run compared against the quick baseline) are reported as SKIP, not
failed — the gate only compares like with like. Baseline topologies must
all be present in the fresh artifact (the quick grid is a subset of the
full grid).

Run from the repo root:
  PYTHONPATH=src python -m benchmarks.kernel_bench --quick
  python tools/check_bench.py
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_FRESH = REPO_ROOT / "BENCH_kernels.json"
DEFAULT_BASELINE = (
    REPO_ROOT / "benchmarks" / "baselines" / "BENCH_kernels.baseline.json"
)

# Per-section generator parameters: a section is only compared when ALL
# of these match between baseline and fresh artifact.
PARAMS = {
    "fused": ("m", "layers", "blocks_per_row", "n"),
    "train": ("m", "layers", "block", "blocks_per_row", "n"),
    "serve": (
        "m",
        "layers",
        "blocks_per_row",
        "requests",
        "batch_size",
        "tile_align",
        "min_fill",
        "max_wait",
        "trace",
    ),
}

EXACT = {
    "fused": (
        "pallas_calls_fused",
        "pallas_calls_layered",
        "hbm_activation_roundtrips_eliminated",
    ),
    "train": (
        "pallas_calls_per_step",
        "pallas_calls_forward_only",
        "grid_steps_forward",
        "grid_steps_backward_kernel",
        "layout_per_layer",
        "weight_cotangent_pattern_preserved",
        "loss_decreased",
    ),
}
TOPOLOGY_EXACT = (
    "grid_steps_ell",
    "grid_steps_csr",
    "max_blocks_per_row",
    "mean_blocks_per_row",
)
# Deterministic serve accounting, checked exactly for BOTH arms.
SERVE_EXACT = (
    "requests",
    "engine_steps",
    "rows_served",
    "padded_slots",
    "grid_steps_total",
    "latency_mean",
    "latency_p50",
    "latency_max",
    "deadline_misses",
)


class Gate:
    def __init__(self, tol: float):
        self.tol = tol
        self.rows: list[tuple[str, str, str, str, str]] = []
        self.failed = 0

    def _add(self, section, field, base, fresh, verdict):
        self.rows.append(
            (section, field, _fmt(base), _fmt(fresh), verdict)
        )
        if verdict == "FAIL":
            self.failed += 1

    def exact(self, section, field, base, fresh):
        ok = (
            math.isclose(base, fresh, rel_tol=1e-9, abs_tol=1e-12)
            if isinstance(base, float) or isinstance(fresh, float)
            else base == fresh
        )
        self._add(section, field, base, fresh, "ok" if ok else "FAIL")

    def time(self, section, field, base, fresh):
        ok = fresh <= base * self.tol
        self._add(
            section, field, base, fresh, "ok" if ok else "FAIL"
        )

    def no_worse(self, section, field, base, fresh, eps=1e-9):
        ok = fresh <= base + eps
        self._add(section, field, base, fresh, "ok" if ok else "FAIL")

    def skip(self, section, reason):
        self.rows.append((section, reason, "-", "-", "SKIP"))

    def missing(self, section, what):
        self._add(section, what, "present", "missing", "FAIL")

    def table(self) -> str:
        header = ("section", "field", "baseline", "fresh", "verdict")
        rows = [header, *self.rows]
        widths = [max(len(r[i]) for r in rows) for i in range(5)]
        lines = []
        for j, r in enumerate(rows):
            lines.append(
                "  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
            )
            if j == 0:
                lines.append("  ".join("-" * w for w in widths))
        return "\n".join(lines)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    return s if len(s) <= 32 else s[:29] + "..."


def _topo_key(t: dict) -> tuple:
    return (t["m"], t["block"], t["n"], t["nnz_blocks"], t["skew"])


def _params_match(section: str, base: dict, fresh: dict) -> bool:
    return all(base.get(k) == fresh.get(k) for k in PARAMS[section])


def check(baseline: dict, fresh: dict, tol: float) -> Gate:
    gate = Gate(tol)

    # --- topologies: every baseline topology must appear, steps exact --
    fresh_topos = {_topo_key(t): t for t in fresh.get("topologies", [])}
    for bt in baseline.get("topologies", []):
        key = _topo_key(bt)
        name = f"topo m={key[0]} nnz={key[3]} skew={key[4]}"
        ft = fresh_topos.get(key)
        if ft is None:
            gate.missing(name, "topology")
            continue
        for field in TOPOLOGY_EXACT:
            gate.exact(name, field, bt[field], ft[field])
        for arm in ("ell", "csr", "dense"):
            gate.time(
                name,
                f"xla_time_s.{arm}",
                bt["xla_time_s"][arm],
                ft["xla_time_s"][arm],
            )

    # --- fused / train: exact counts when the generator params match ---
    for section in ("fused", "train"):
        bs, fs = baseline.get(section), fresh.get(section)
        if bs is None:
            continue
        if fs is None:
            gate.missing(section, "section")
            continue
        if not _params_match(section, bs, fs):
            gate.skip(section, "generator params differ (quick vs full)")
            continue
        for field in EXACT[section]:
            gate.exact(section, field, bs[field], fs[field])
        for field, bt in bs.get("xla_time_s", {}).items():
            gate.time(section, f"xla_time_s.{field}", bt, fs["xla_time_s"][field])

    # --- serve: deterministic accounting exact, pad waste gated -------
    bs, fs = baseline.get("serve"), fresh.get("serve")
    if bs is not None:
        if fs is None:
            gate.missing("serve", "section")
        elif not _params_match("serve", bs, fs):
            gate.skip("serve", "trace/knobs differ from baseline")
        else:
            gate.exact(
                "serve", "resident_path_used",
                bs["resident_path_used"], fs["resident_path_used"],
            )
            for arm in ("static", "continuous"):
                for field in SERVE_EXACT:
                    gate.exact(
                        f"serve.{arm}", field, bs[arm][field], fs[arm][field]
                    )
            # the headline guarantee: pad waste must not regress vs the
            # baseline, and continuous must still beat static outright
            gate.no_worse(
                "serve",
                "continuous.pad_slot_fraction",
                bs["continuous"]["pad_slot_fraction"],
                fs["continuous"]["pad_slot_fraction"],
            )
            strict = (
                fs["continuous"]["pad_slot_fraction"]
                < fs["static"]["pad_slot_fraction"]
            )
            gate._add(
                "serve",
                "continuous < static pad fraction",
                fs["static"]["pad_slot_fraction"],
                fs["continuous"]["pad_slot_fraction"],
                "ok" if strict else "FAIL",
            )
            for arm in ("static", "continuous"):
                gate.time(
                    "serve",
                    f"wall_time_s.{arm}",
                    bs["wall_time_s"][arm],
                    fs["wall_time_s"][arm],
                )
    return gate


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", nargs="?", default=str(DEFAULT_FRESH))
    ap.add_argument("baseline", nargs="?", default=str(DEFAULT_BASELINE))
    ap.add_argument(
        "--tol",
        type=float,
        default=25.0,
        help="wall-clock regression factor tolerated (runner noise)",
    )
    args = ap.parse_args()

    try:
        fresh = json.loads(pathlib.Path(args.fresh).read_text())
    except FileNotFoundError:
        print(
            f"error: fresh artifact {args.fresh} not found — run "
            "`PYTHONPATH=src python -m benchmarks.kernel_bench --quick` first",
            file=sys.stderr,
        )
        return 2
    baseline = json.loads(pathlib.Path(args.baseline).read_text())

    gate = check(baseline, fresh, args.tol)
    print(gate.table())
    n_checks = sum(1 for r in gate.rows if r[4] != "SKIP")
    if gate.failed:
        print(
            f"\nbench gate: {gate.failed}/{n_checks} checks FAILED against "
            f"{args.baseline}",
            file=sys.stderr,
        )
        print(
            "If the change is intentional (new kernel schedule, new "
            "trace), regenerate the baseline:\n"
            "  PYTHONPATH=src python -m benchmarks.kernel_bench --quick\n"
            f"  cp {DEFAULT_FRESH.name} {args.baseline}",
            file=sys.stderr,
        )
        return 1
    print(f"\nbench gate: all {n_checks} checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
